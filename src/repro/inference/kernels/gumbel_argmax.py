"""Fused gumbel-max token sampling: ONE pallas_call from counter bits to
token ids.

The gumbel-max trick samples ``softmax(logits / temperature)`` by adding
independent standard-Gumbel noise to the scaled logits and taking the
argmax.  A two-pass implementation materializes the ``(vocab, batch)``
noise block in HBM and then reduces it against the logits — at decode
batch sizes that noise block is the largest tensor the sampler touches.
This kernel fuses the whole chain instead:

  counter bits (ThundeRiNG ctr mode, one leaf tag per live sequence)
    -> u = top-24-bit uniform
    -> g = -log(-log(u))                (the grammar's "gumbel" stage)
    -> score = fma_guard(logit * inv_temp) + g, top-k mask
    -> running (max, argmax) over vocab tiles
    -> (batch,) int32 token ids

per tile in VMEM, so neither the uint32 bit block nor the f32 noise
block ever reaches HBM (jaxpr-asserted in tests/test_inference.py).

Layout: scores live ``(vocab, batch)`` — vocab on sublanes, sequences on
lanes — because that is the generation layout (counters advance along
the T axis = vocab, leaf tags select the S axis = sequences), so the
bits are consumed exactly where they are produced, with no in-kernel
transpose.  Callers pass logits already transposed.

The grid is ``(batch_tiles, vocab_tiles)`` with vocab minor: each batch
tile's ``(1, bs)`` output block is revisited across the vocab tiles
while the running best value/index carries in VMEM scratch, and the
token ids are written once on the last vocab tile.

Tie-breaking matches ``jnp.argmax`` (first index wins): within a tile
the argmax is the *minimum* row index attaining the tile max (a
Mosaic-safe where+min reduction, no 1-D iota), and across tiles a later
tile only takes over on a STRICTLY greater max.

Bit-exactness contract (shared with the two-pass oracle below): both
paths run the identical elementwise chain — ``sampler.gumbel_from_bits``
on engine-identical bits, the ``fma_guard``-pinned logit scaling, the
same masked first-argmax — so scores agree bit-for-bit at tile-multiple
shapes and to the usual few-ULP libm slack at padded tiles; token
parity additionally requires no two scores within that slack of the
column max, which fixed-seed tests assert empirically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np

from repro.core import sampler as sampler_mod

DEFAULT_BLOCK_V = 512     # vocab (sublane) tile
DEFAULT_BLOCK_B = 256     # batch (lane) tile

_NEG_INF = np.float32(-np.inf)
_I32_MAX = np.int32(np.iinfo(np.int32).max)


def gumbel_scores(bits: jnp.ndarray, logits: jnp.ndarray,
                  inv_temp: np.float32) -> jnp.ndarray:
    """Perturbed scores: ``fma_guard(logits * inv_temp) + gumbel(bits)``.

    The ONE scoring transform shared by the fused kernel body and the
    two-pass oracle — sharing it (like ``sampler.apply`` across the
    engine backends) is what makes the parity check a statement about
    the kernel's dataflow rather than about two re-implementations.
    ``fma_guard`` pins the scaled logit before the add so XLA:CPU cannot
    contract it shape-dependently (see ``repro.core.sampler``).
    """
    g = sampler_mod.gumbel_from_bits(bits)
    return sampler_mod.fma_guard(logits * inv_temp) + g


def argmax_first(scores: jnp.ndarray) -> jnp.ndarray:
    """Column-wise argmax over axis 0, FIRST max index wins — (B,) int32.

    Expressed as max + (where, min-iota) instead of ``jnp.argmax`` so
    the identical reduction runs inside the Pallas kernel body (Mosaic
    has no native argmax; ``broadcasted_iota`` is its 2-D-safe iota).
    """
    m = jnp.max(scores, axis=0, keepdims=True)
    row = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    return jnp.min(jnp.where(scores == m, row, _I32_MAX), axis=0)


def _masked(scores: jnp.ndarray, logits: jnp.ndarray,
            thresh: jnp.ndarray) -> jnp.ndarray:
    """Top-k mask: tokens whose LOGIT is below the per-sequence k-th
    largest logit can never win (-inf score).  Thresholding on raw
    logits (not scores) keeps the kept set independent of the noise —
    the standard top-k-then-sample semantics."""
    return jnp.where(logits >= thresh, scores, _NEG_INF)


# ---------------------------------------------------------------------------
# Fused kernel
# ---------------------------------------------------------------------------

def _gumbel_argmax_kernel(logits_ref, root_hi_ref, root_lo_ref,
                          ctr_hi_ref, ctr_lo_ref, h_hi_ref, h_lo_ref,
                          thresh_ref, o_ref, best_ref, besti_ref, *,
                          inv_temp: np.float32, deco: str, block_v: int,
                          n_v_tiles: int):
    j = pl.program_id(1)               # vocab tile (minor -> o_ref revisit)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full(best_ref.shape, _NEG_INF, jnp.float32)
        besti_ref[...] = jnp.zeros(besti_ref.shape, jnp.int32)

    rh, rl = root_hi_ref[...], root_lo_ref[...]          # (bv, 1)
    ch, cl = ctr_hi_ref[...], ctr_lo_ref[...]            # (bv, 1)
    hh, hl = h_hi_ref[...], h_lo_ref[...]                # (1, bb)
    logits = logits_ref[...]                             # (bv, bb)
    bits = sampler_mod.ctr_bits((rh, rl), (ch, cl), (hh, hl), deco=deco)
    score = _masked(gumbel_scores(bits, logits, inv_temp), logits,
                    thresh_ref[...])

    tile_max = jnp.max(score, axis=0, keepdims=True)     # (1, bb)
    row = (jax.lax.broadcasted_iota(jnp.int32, score.shape, 0)
           + j * block_v)                                # global vocab index
    tile_arg = jnp.min(jnp.where(score == tile_max, row, _I32_MAX),
                       axis=0, keepdims=True)
    # strictly-greater carry: ties resolve to the earlier (lower-index)
    # tile, matching argmax_first over the full column
    take = tile_max > best_ref[...]
    besti_ref[...] = jnp.where(take, tile_arg, besti_ref[...])
    best_ref[...] = jnp.where(take, tile_max, best_ref[...])

    @pl.when(j == n_v_tiles - 1)
    def _emit():
        o_ref[...] = besti_ref[...]


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def fused_argmax(logits_t: jnp.ndarray, h, roots, ctr_rows,
                 thresh: jnp.ndarray, *, inv_temp: np.float32,
                 deco: str = "splitmix64", block_v: int = DEFAULT_BLOCK_V,
                 block_b: int = DEFAULT_BLOCK_B,
                 interpret: bool = False) -> jnp.ndarray:
    """(B,) int32 sampled tokens from (V, B) transposed logits — one
    pallas_call, no noise/bit block in HBM.

    logits_t: (V, B) float32 (vocab-major).  h: ((B,), (B,)) u32 leaf
    offsets — one PER SEQUENCE (each live sequence is a tenant; its tag
    selects its independent stream).  roots / ctr_rows: ((V,), (V,)) u32
    per-vocab-row root states and counters for this decode step's
    counter window (``engine.root_and_ctr_rows``).  thresh: (B,) f32
    per-sequence top-k logit threshold (-inf disables masking).
    """
    V, B = logits_t.shape
    bv = min(block_v, _pad_to(V, 8))
    bv = max(8, bv - bv % 8)
    bb = min(block_b, _pad_to(B, 128))
    Vp, Bp = _pad_to(V, bv), _pad_to(B, bb)

    # vocab padding loses by construction: -inf logits either fail the
    # top-k compare (thresh finite) or score fma_guard(-inf)+g ~ -1e30;
    # batch padding (+inf thresh -> all-masked column) yields token 0,
    # sliced off below.
    lt = jnp.pad(logits_t.astype(jnp.float32), ((0, Vp - V), (0, Bp - B)),
                 constant_values=_NEG_INF)
    th = jnp.pad(thresh.astype(jnp.float32), (0, Bp - B),
                 constant_values=np.float32(np.inf)).reshape(1, Bp)

    def pad_col(v):  # (V,) -> (Vp, 1)
        return jnp.pad(v, (0, Vp - V)).reshape(Vp, 1)

    def pad_row(v):  # (B,) -> (1, Bp)
        return jnp.pad(v, (0, Bp - B)).reshape(1, Bp)

    n_v = Vp // bv
    col = pl.BlockSpec((bv, 1), lambda i, j: (j, 0))
    lane = pl.BlockSpec((1, bb), lambda i, j: (0, i))
    out = pl.pallas_call(
        functools.partial(_gumbel_argmax_kernel, inv_temp=inv_temp,
                          deco=deco, block_v=bv, n_v_tiles=n_v),
        grid=(Bp // bb, n_v),
        in_specs=[pl.BlockSpec((bv, bb), lambda i, j: (j, i)),
                  col, col, col, col, lane, lane, lane],
        out_specs=pl.BlockSpec((1, bb), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Bp), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, bb), jnp.float32),
                        pltpu.VMEM((1, bb), jnp.int32)],
        interpret=interpret,
    )(lt, pad_col(roots[0]), pad_col(roots[1]),
      pad_col(ctr_rows[0]), pad_col(ctr_rows[1]),
      pad_row(h[0]), pad_row(h[1]), th)
    return out[0, :B]


# ---------------------------------------------------------------------------
# Two-pass oracle
# ---------------------------------------------------------------------------

def twopass_argmax(logits_t: jnp.ndarray, noise: jnp.ndarray,
                   thresh: jnp.ndarray, *,
                   inv_temp: np.float32) -> jnp.ndarray:
    """(B,) int32 tokens from a MATERIALIZED (V, B) gumbel noise block.

    The reference the fused kernel is checked against: ``noise`` comes
    from ``engine.generate`` with the ``"gumbel"`` sampler stage on the
    ref/xla backend (bit-identical bits by the engine's parity tests),
    and the scoring/masking/argmax here reuses the kernel's own helpers,
    so fused-vs-two-pass disagreement isolates the kernel's tiling —
    not the math.
    """
    logits_t = logits_t.astype(jnp.float32)
    score = sampler_mod.fma_guard(logits_t * inv_temp) + noise
    return argmax_first(_masked(score, logits_t, thresh.reshape(1, -1)))
