"""Fixed-capacity slot pool: live decode sequences as RandService tenants.

Continuous batching keeps a fixed number of decode *slots* hot and
churns sequences through them: a sequence joins the batch when a slot
frees up, decodes until it finishes, and its slot is immediately
reusable.  The randomness-safety question under that churn is the whole
point of this module — when sequence B reuses the slot sequence A just
vacated, NOTHING B draws may overlap anything A ever consumed, and a
crash-restarted run must reassign the exact same sequences to the exact
same slots so its token streams replay bit-identically.

The mapping onto the service tiers:

  * each live sequence registers as a tenant (``TenantRegistry``) — its
    blake2s region tag is its noise column in the decode kernel, stable
    across processes because it derives from the seq id alone;
  * each SLOT owns a per-slot admission channel
    (``inference/slot/<i>``); occupant ``o`` of slot ``i`` draws its
    admission randomness (target length etc.) from the deterministic
    window ``[o * draw_rows, (o+1) * draw_rows)`` of that channel, so
    slot assignment alone pins every admission draw;
  * retiring a sequence retires its tenant row
    (``TenantRegistry.retire``) and RELEASES the slot channel
    (``BlockService.release(name)``), which fences the channel floor at
    its high-water mark — the ledger-level proof that a
    retired-and-reused slot can never re-lease a window its previous
    occupant consumed (``tests/test_inference.py`` asserts this).

Replay: admissions happen at deterministic (slot, occupant-ordinal)
coordinates, so a restarted pool re-admits the same sequences into the
same slots; admission draws use lease-or-regenerate — a window already
committed in the restored ledger regenerates bit-identically instead
of double-leasing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.runtime import blocks
from repro.service import tenants


def slot_channel(slot: int) -> str:
    """Per-slot admission channel name."""
    return f"inference/slot/{slot}"


@dataclasses.dataclass
class Sequence:
    """One sequence's lifetime in the pool."""
    seq_id: str
    tenant_id: str
    tag: int                 # leaf tag = noise column selector
    slot: int
    occupant: int            # nth occupant of this slot (admission ordinal)
    arrival_step: int        # decode step at which the sequence was admitted
    target_len: int          # tokens to generate before it finishes
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def position(self) -> int:
        return len(self.tokens)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.target_len


class SlotPool:
    """``capacity`` decode slots over one BlockService + TenantRegistry.

    ``admit`` assigns the lowest free slot (deterministic given the
    admission order, which the scheduler makes deterministic given the
    seed), registers the sequence's tenant, opens the slot channel, and
    draws the sequence's target length from the slot channel's
    occupant-ordinal window.  ``retire`` frees the slot, retires the
    tenant row, and releases the slot channel (floor-fencing its
    ledger).
    """

    def __init__(self, service: blocks.BlockService,
                 registry: tenants.TenantRegistry, *, capacity: int,
                 min_len: int = 4, len_spread: int = 29,
                 draw_rows: int = 8, journal=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if min_len < 1 or len_spread < 0:
            raise ValueError(f"need min_len >= 1 and len_spread >= 0, got "
                             f"min_len={min_len} len_spread={len_spread}")
        self.service = service
        self.registry = registry
        self.capacity = int(capacity)
        self.min_len = int(min_len)
        self.len_spread = int(len_spread)
        self.draw_rows = int(draw_rows)
        self.journal = journal
        self._slots: List[Optional[Sequence]] = [None] * capacity
        # occupant ordinals survive retire: the (slot, ordinal) pair is
        # the admission-draw address, so it must count every occupant a
        # slot has EVER had, not just the live one.
        self._occupants: List[int] = [0] * capacity
        self.admitted = 0
        self.retired = 0

    # -- queries -----------------------------------------------------------

    def has_free(self) -> bool:
        return any(s is None for s in self._slots)

    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    def active(self) -> List[Sequence]:
        """Live sequences, slot order (the decode batch)."""
        return [s for s in self._slots if s is not None]

    def at(self, slot: int) -> Optional[Sequence]:
        return self._slots[slot]

    # -- admission draw ----------------------------------------------------

    def _admission_draw(self, slot: int, occupant: int) -> np.ndarray:
        """(draw_rows,) uniforms from the slot channel's occupant window,
        via lease-or-regenerate (replay-safe)."""
        name = slot_channel(slot)
        self.service.open(name, num_streams=1, sampler="uniform",
                          out_dtype="float32")
        lo = occupant * self.draw_rows
        lease = None
        try:
            lease = self.service.lease(name, self.draw_rows, at=lo)
        except blocks.LeaseError:
            pass  # already journaled by a previous owner: regenerate
        u = np.asarray(self.service.regenerate(name, lo, self.draw_rows))
        if lease is not None:
            lease.commit()
            if self.journal is not None:
                self.journal.append_window(name, lo, lo + self.draw_rows)
        return u[:, 0]

    # -- lifecycle ---------------------------------------------------------

    def admit(self, seq_id: str, arrival_step: int) -> Sequence:
        """Admit ``seq_id`` into the lowest free slot."""
        slot = next((i for i, s in enumerate(self._slots) if s is None),
                    None)
        if slot is None:
            raise RuntimeError(f"no free slot for {seq_id!r} "
                               f"(capacity {self.capacity})")
        occupant = self._occupants[slot]
        self._occupants[slot] = occupant + 1
        tenant = self.registry.register(seq_id)
        u = self._admission_draw(slot, occupant)
        target_len = self.min_len + int(float(u[0]) * (self.len_spread + 1))
        seq = Sequence(seq_id=seq_id, tenant_id=seq_id, tag=tenant.tag(0),
                       slot=slot, occupant=occupant,
                       arrival_step=arrival_step, target_len=target_len)
        self._slots[slot] = seq
        self.admitted += 1
        return seq

    def retire(self, slot: int) -> Sequence:
        """Finish the sequence in ``slot``; the slot is free afterwards.

        Tenant row and slot channel are both retired — the channel
        release fences the slot-channel floor so the NEXT occupant's
        admission window can never overlap this occupant's (the ledger
        also enforces it structurally: occupant ordinals never repeat).
        """
        seq = self._slots[slot]
        if seq is None:
            raise ValueError(f"slot {slot} is not occupied")
        self._slots[slot] = None
        self.registry.retire(seq.tenant_id)
        self.service.release(slot_channel(slot))
        self.retired += 1
        return seq

    def occupancy(self) -> float:
        return self.num_active() / self.capacity
