"""Continuous batcher: Poisson arrivals, slot churn, one fused sampling
call per decode step.

The decode loop the offline harness runs:

  1. arrivals whose time has come join the prefill queue (arrival gaps
     are drawn from the service's own ``exponential(rate)`` sampler
     stage — the RNG tier dogfooding its distribution grammar);
  2. queued sequences admit into free slots (``SlotPool.admit``);
  3. one ``GumbelMaxSampler.sample_step`` samples EVERY live sequence's
     next token — one coalesced per-class engine call for the whole
     step (the ``calls_per_step <= 1.25`` CI gate measures exactly
     this meter);
  4. finished sequences retire, freeing their slots for step 5's
     admissions.

Every stochastic input is counter-addressed at schedule-deterministic
coordinates — arrival gaps at block ordinals of one arrivals channel,
admission draws at (slot, occupant) ordinals, decode noise at
``step * vocab`` of the class channel — so the whole run is a pure
function of ``ScheduleConfig``: re-running it, or crash-replaying it
from the journal (``restore_into`` + lease-or-regenerate), reproduces
the per-sequence token transcripts bit-identically.  The digest over
those transcripts is the cross-run/replay check CI compares.

Logits come from :class:`SyntheticLogitModel` — a pure hash of
(sequence, position, token) — standing in for a real model forward
pass; it is deliberately NOT drawn from the service so the randomness
accounting above stays exactly "admission + arrivals + one decode
window per step".
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.u64 import U32
from repro.runtime import blocks, fault
from repro.service import audit, tenants
from repro.inference import slots as slots_mod
from repro.inference.sampling import (ActiveSeq, GumbelMaxSampler,
                                      SamplingSpec)

ARRIVAL_CHANNEL = "inference/arrivals"
ARRIVAL_BLOCK = 64


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """One offline continuous-batching run, fully determined by this."""
    capacity: int = 64        # decode slots (the batch dimension)
    vocab: int = 512
    sequences: int = 128      # total sequences to serve
    rate: float = 8.0         # Poisson arrival rate (sequences per step)
    min_len: int = 4          # shortest target length
    len_spread: int = 29      # target_len in [min_len, min_len+len_spread]
    seed: int = 0
    temperature: float = 1.0
    top_k: int = 0
    path: str = "fused"       # sampling path: fused | xla | ref
    max_steps: int = 100_000  # hard stop (safety bound)
    logit_scale: float = 6.0

    def spec(self) -> SamplingSpec:
        return SamplingSpec(temperature=self.temperature, top_k=self.top_k)


class ArrivalProcess:
    """Poisson arrivals from the service's own exponential sampler stage.

    Inter-arrival gaps (units: decode steps) are ``exponential(rate)``
    draws from one arrivals channel, consumed in fixed ``ARRIVAL_BLOCK``
    windows at block ordinals — lease-or-regenerate, journaled — and
    cumulated into integer arrival steps at construction, so the whole
    arrival schedule is pinned before the first decode step (and pinned
    identically by a replaying run).
    """

    def __init__(self, service: blocks.BlockService, *, rate: float,
                 count: int, journal=None):
        if rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {rate}")
        spec = f"exponential({rate})"
        service.open(ARRIVAL_CHANNEL, num_streams=1, sampler=spec,
                     out_dtype="float32")
        gaps: List[float] = []
        block = 0
        while len(gaps) < count:
            lo = block * ARRIVAL_BLOCK
            lease = None
            try:
                lease = service.lease(ARRIVAL_CHANNEL, ARRIVAL_BLOCK, at=lo)
            except blocks.LeaseError:
                pass  # journaled by the previous owner: regenerate
            blk = np.asarray(service.regenerate(ARRIVAL_CHANNEL, lo,
                                                ARRIVAL_BLOCK))
            if lease is not None:
                lease.commit()
                if journal is not None:
                    journal.append_window(ARRIVAL_CHANNEL, lo,
                                          lo + ARRIVAL_BLOCK)
            gaps.extend(float(g) for g in blk[:, 0])
            block += 1
        t = 0.0
        steps: List[int] = []
        for g in gaps[:count]:
            t += g
            steps.append(int(t))
        self.arrival_steps = steps          # non-decreasing

    def due(self, step: int, start: int) -> int:
        """Number of arrivals in ``[start, count)`` due by ``step``."""
        n = start
        while (n < len(self.arrival_steps)
               and self.arrival_steps[n] <= step):
            n += 1
        return n


class SyntheticLogitModel:
    """Pure-hash (capacity, vocab) logits: fmix32(seq ^ position ^ token).

    A deterministic stand-in for a model forward pass — every (sequence,
    position, token) cell is an independent-looking value in
    ``[0, scale)``, identical across processes and backends (integer
    hashing + one exact float scale), so token-stream determinism checks
    exercise the SAMPLER's reproducibility, not a model's.
    """

    def __init__(self, capacity: int, vocab: int, scale: float = 6.0):
        self.capacity = capacity
        self.vocab = vocab
        P1, P2 = U32(0x9E3779B1), U32(0x85EBCA77)
        sc = np.float32(scale * 2.0 ** -24)

        def fmix32(x):
            x = x ^ (x >> U32(16))
            x = x * U32(0x85EBCA6B)
            x = x ^ (x >> U32(13))
            x = x * U32(0xC2B2AE35)
            return x ^ (x >> U32(16))

        def logits(seq_hash, position):
            col = jnp.arange(vocab, dtype=jnp.uint32).reshape(1, vocab)
            x = (seq_hash.reshape(capacity, 1)
                 ^ (position.reshape(capacity, 1) * P1) ^ (col * P2))
            return (fmix32(x) >> U32(8)).astype(jnp.float32) * sc

        self._fn = jax.jit(logits)

    @staticmethod
    def seq_hash(seq_id: str) -> int:
        return int.from_bytes(
            hashlib.blake2s(seq_id.encode(), digest_size=4).digest(),
            "little")

    def __call__(self, seq_hash: np.ndarray,
                 position: np.ndarray) -> jnp.ndarray:
        return self._fn(jnp.asarray(seq_hash, dtype=jnp.uint32),
                        jnp.asarray(position, dtype=jnp.uint32))


@dataclasses.dataclass
class RunResult:
    """One offline run's outcome (transcripts + meters)."""
    transcripts: Dict[str, List[int]]
    digest: str
    decode_steps: int
    total_tokens: int
    admitted: int
    retired: int
    occupancy: float              # mean live-slots / capacity over steps
    step_seconds: List[float]     # wall time of each decode step
    sampler_stats: Dict[str, float]

    def latency_percentiles(self) -> Dict[str, float]:
        if not self.step_seconds:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        s = np.asarray(self.step_seconds)
        return {"p50_ms": float(np.percentile(s, 50) * 1e3),
                "p99_ms": float(np.percentile(s, 99) * 1e3)}


def transcript_digest(transcripts: Dict[str, List[int]]) -> str:
    """Order-independent sha256 over per-sequence token streams."""
    h = hashlib.sha256()
    for seq_id in sorted(transcripts):
        h.update(seq_id.encode())
        h.update(np.asarray(transcripts[seq_id], np.int32).tobytes())
    return h.hexdigest()


class ContinuousBatcher:
    """The decode loop; see the module docstring for the step anatomy.

    ``journal``: an ``audit.Journal`` — when it already holds entries
    (restart), its windows are restored and FENCED into the service
    before any channel opens, and the schedule re-executes from step 0
    with every journaled draw regenerating bit-identically.
    ``fault_plan``: scripted faults keyed on the decode step index
    (``kill`` = ``os._exit(1)`` BEFORE the step's journal append —
    SIGKILL semantics; ``slow`` = sleep, a straggler step).
    """

    def __init__(self, config: ScheduleConfig, *,
                 journal: Optional[audit.Journal] = None,
                 fault_plan: Optional[fault.FaultPlan] = None):
        self.config = config
        self.journal = journal
        self.service = blocks.BlockService(seed=config.seed)
        if journal is not None and journal.entries:
            journal.restore_into(self.service, fence=True)
        self.registry = tenants.TenantRegistry()
        self.sampler = GumbelMaxSampler(
            self.service, self.registry, vocab=config.vocab,
            capacity=config.capacity, spec=config.spec(), path=config.path,
            journal=journal)
        self.pool = slots_mod.SlotPool(
            self.service, self.registry, capacity=config.capacity,
            min_len=config.min_len, len_spread=config.len_spread,
            journal=journal)
        self.arrivals = ArrivalProcess(
            self.service, rate=config.rate, count=config.sequences,
            journal=journal)
        self.logit_model = SyntheticLogitModel(
            config.capacity, config.vocab, config.logit_scale)
        self.injector = (fault.FaultInjector(fault_plan)
                         if fault_plan else None)

    @staticmethod
    def seq_id(index: int) -> str:
        return f"seq/{index:06d}"

    def _fire_fault(self, step: int) -> None:
        if self.injector is None:
            return
        spec = self.injector.fire(0, step)
        if spec is None:
            return
        if spec.kind == "kill":
            # SIGKILL semantics: no journal write for this step, no
            # cleanup — the torn-tail repair and lease-or-regenerate
            # must carry the restart
            os._exit(1)
        elif spec.kind == "slow":
            time.sleep(spec.seconds)
        else:
            raise ValueError(f"unsupported decode fault {spec.kind!r} "
                             f"(have kill, slow)")

    def run(self) -> RunResult:
        cfg = self.config
        transcripts: Dict[str, List[int]] = {}
        hashes = np.zeros(cfg.capacity, dtype=np.uint32)
        positions = np.zeros(cfg.capacity, dtype=np.uint32)
        step_seconds: List[float] = []
        live_sum = 0
        next_arrival = 0
        step = 0
        decode_steps = 0
        while step < cfg.max_steps:
            # 1+2: due arrivals admit into free slots (FIFO prefill queue)
            due = self.arrivals.due(step, next_arrival)
            while next_arrival < due and self.pool.has_free():
                sid = self.seq_id(next_arrival)
                seq = self.pool.admit(sid, step)
                transcripts[sid] = seq.tokens
                hashes[seq.slot] = U32(
                    SyntheticLogitModel.seq_hash(sid))
                positions[seq.slot] = 0
                next_arrival += 1
            active = self.pool.active()
            if not active:
                if next_arrival >= cfg.sequences and self.pool.num_active() == 0:
                    break   # drained: every sequence served
                step += 1   # idle step: nothing due yet
                continue

            # 3: one coalesced sampling call for every live sequence
            self._fire_fault(decode_steps)
            t0 = time.perf_counter()
            logits = self.logit_model(hashes, positions)
            batch = [ActiveSeq(slot=s.slot, seq_id=s.seq_id,
                               tenant_id=s.tenant_id, tag=s.tag,
                               position=s.position) for s in active]
            tokens = self.sampler.sample_step(decode_steps, logits, batch)
            step_seconds.append(time.perf_counter() - t0)
            live_sum += len(active)
            decode_steps += 1

            # 4: record tokens, retire finished sequences (slot order)
            for s in active:
                s.tokens.append(int(tokens[s.slot]))
                positions[s.slot] += U32(1)
                if s.done:
                    self.pool.retire(s.slot)
            step += 1

        return RunResult(
            transcripts=transcripts,
            digest=transcript_digest(transcripts),
            decode_steps=decode_steps,
            total_tokens=sum(len(t) for t in transcripts.values()),
            admitted=self.pool.admitted,
            retired=self.pool.retired,
            occupancy=(live_sum / (decode_steps * cfg.capacity)
                       if decode_steps else 0.0),
            step_seconds=step_seconds,
            sampler_stats=self.sampler.stats())
