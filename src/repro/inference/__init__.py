"""Continuous-batching inference tier: slot-based decode serving with
in-kernel bits-to-token sampling.

The seventh layer — above ``service`` — turning the randomness service
into a token-serving consumer:

  * ``kernels``   — the fused gumbel-max Pallas kernel (counter bits ->
    token ids in one pallas_call) and its two-pass oracle;
  * ``sampling``  — :class:`GumbelMaxSampler`: one leased counter
    window + one engine call per decode step, journaled;
  * ``slots``     — :class:`SlotPool`: live sequences as tenants,
    slot churn as deterministic region retire-and-reuse;
  * ``scheduler`` — :class:`ContinuousBatcher`: Poisson arrivals from
    the service's own ``exponential`` stage, admission, per-step churn;
  * ``harness``   — the offline benchmark + crash-replay CLI
    (``python -m repro.inference``).

See ``docs/inference.md`` for the slot lifecycle, the kernel contract,
and the latency methodology.
"""
from repro.inference.sampling import (ActiveSeq, GumbelMaxSampler,  # noqa: F401
                                      SamplingSpec)
from repro.inference.slots import Sequence, SlotPool  # noqa: F401
from repro.inference.scheduler import (ContinuousBatcher,  # noqa: F401
                                       ScheduleConfig, RunResult,
                                       SyntheticLogitModel,
                                       transcript_digest)
from repro.inference.harness import OfflineReport, run_offline  # noqa: F401
