PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke install-dev service service-smoke fleet fleet-smoke roofline roofline-full inference inference-smoke

install-dev:
	$(PY) -m pip install -e ".[test]"

test:              ## tier-1 suite
	$(PY) -m pytest -x -q

test-fast:         ## tier-1 minus the slow end-to-end tests
	$(PY) -m pytest -x -q -m "not slow"

bench:             ## full benchmark battery (CSV to stdout)
	$(PY) -m benchmarks.run

bench-smoke:       ## CI-sized throughput + sampler smoke (parity, timing, BENCH_throughput.json)
	$(PY) -m benchmarks.throughput

service:           ## RandService: 1024-tenant burst + replay check, then serve until SIGINT (graceful drain)
	$(PY) -m repro.service --burst 1024 --tenants 1024 --verify-replay --linger 600

service-smoke:     ## RandService burst bench rows only (service/* in BENCH_throughput.json)
	$(PY) -m benchmarks.throughput service

fleet:             ## 2-shard wire fleet (pipelined binary clients, coalescing+pools on): kill-mid-burst failover, digest vs no-fault, union replay
	rm -rf /tmp/repro-fleet
	$(PY) -m repro.service --fleet 2 --burst 256 --tenants 64 \
	    --journal-dir /tmp/repro-fleet --fault-plan kill@128 --verify-replay

fleet-smoke:       ## fleet bench rows (binary/json pair, hammer/unique/kill; fleet/* in BENCH_throughput.json)
	$(PY) -m benchmarks.throughput fleet

inference:         ## continuous batcher: fused/xla parity run, then kill-mid-run + journal replay, digest vs no-fault
	rm -rf /tmp/repro-inference && mkdir -p /tmp/repro-inference
	$(PY) -m repro.inference --batch 16 --vocab 256 --sequences 48 --rate 4 \
	    --seed 7 --parity --digest-out /tmp/repro-inference/base.digest
	-$(PY) -m repro.inference --batch 16 --vocab 256 --sequences 48 --rate 4 \
	    --seed 7 --journal /tmp/repro-inference/journal.jsonl --fault-plan kill@40
	$(PY) -m repro.inference --batch 16 --vocab 256 --sequences 48 --rate 4 \
	    --seed 7 --journal /tmp/repro-inference/journal.jsonl \
	    --digest-out /tmp/repro-inference/replay.digest
	cmp /tmp/repro-inference/base.digest /tmp/repro-inference/replay.digest
	@echo "inference: kill-mid-run replay digest == no-fault digest"

inference-smoke:   ## inference bench rows (offline parity run + step micro; inference/* in BENCH_throughput.json)
	$(PY) -m benchmarks.throughput inference

roofline:          ## roofline smoke + regression gate (merges roofline/* rows, fails if fused/donated regress)
	$(PY) -m benchmarks.roofline --check

roofline-full:     ## full roofline sweep (S=T=2048, all sampler classes) + gate
	$(PY) -m benchmarks.roofline --full --check
