"""Generate EXPERIMENTS.md markdown tables from experiments/dryrun/*.json."""
import glob
import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"


def fmt_b(n):
    return f"{n / 2**30:.2f}"


def main():
    rows = []
    for f in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        with open(f) as fh:
            rep = json.load(fh)
        tag = os.path.basename(f)[:-5]
        parts = tag.split("__")
        rep["_tag"] = tag
        rep["_mesh_kind"] = parts[2] if len(parts) > 2 else "?"
        rows.append(rep)

    # --- dry-run table (both meshes) ---
    print("### Dry-run matrix\n")
    print("| arch | shape | mesh | status | mem/dev GiB | compile s | HLO lines |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if "__" not in r["_tag"] or r["_tag"].count("__") > 2:
            continue
        arch, shape = r["arch"], r["shape"]
        mesh = r.get("mesh", "-")
        if r.get("skipped"):
            print(f"| {arch} | {shape} | {r['_mesh_kind']} | SKIP (full attention) | - | - | - |")
            continue
        if r.get("error"):
            print(f"| {arch} | {shape} | {mesh} | FAIL | - | - | - |")
            continue
        mem = fmt_b(r["memory"]["total_bytes_per_device"])
        print(f"| {arch} | {shape} | {mesh} | OK | {mem} | "
              f"{r['compile_s']} | {r.get('hlo_lines', '-')} |")

    # --- roofline table (single-pod only) ---
    print("\n### Roofline (single-pod 16x16, per-chip terms)\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck |"
          " MODEL_FLOPS | useful ratio | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["_mesh_kind"] != "pod" or r.get("skipped") or r.get("error"):
            continue
        if "roofline" not in r or "cost_fit" not in r:
            continue
        rf = r["roofline"]
        dom = rf["bottleneck"].replace("_s", "")
        note = {
            "compute": "raise MFU: fuse/bf16",
            "memory": "cut bytes: fusion, flash-attn kernel, bf16 params",
            "collective": "cut comm: bf16 gathers, overlap, EP layout",
        }[dom]
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} | "
              f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | {dom} | "
              f"{rf['model_flops_total']:.2e} | "
              f"{rf['useful_flops_ratio']:.2f} | {note} |")


if __name__ == "__main__":
    main()
