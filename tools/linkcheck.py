#!/usr/bin/env python
"""Markdown link checker (offline): every relative link must resolve.

Usage: python tools/linkcheck.py README.md docs EXPERIMENTS.md ...

Scans the given markdown files (directories are walked for ``*.md``)
for inline links/images ``[text](target)`` and reference definitions
``[ref]: target``, and fails if a relative target (optionally with a
``#fragment``) does not exist on disk relative to the containing file.
``http(s)``/``mailto`` links are only checked syntactically (no
network in CI).  Run by the CI ``docs`` job over README/docs/
EXPERIMENTS/DESIGN so the documentation tree cannot rot silently.
"""
from __future__ import annotations

import pathlib
import re
import sys

# inline [text](target) — target up to the first unescaped ')'; skips
# fenced code blocks and inline code spans below
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_CODE = re.compile(r"`[^`]*`")


def iter_md_files(args):
    for arg in args:
        p = pathlib.Path(arg)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p
        else:
            raise SystemExit(f"linkcheck: not a markdown file or dir: {p}")


def check_file(path: pathlib.Path) -> list:
    text = _CODE.sub("`code`", _FENCE.sub("```fence```", path.read_text()))
    errors = []
    targets = _INLINE.findall(text) + _REFDEF.findall(text)
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            continue  # intra-page anchors: not resolvable without a TOC
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv) -> int:
    if not argv:
        argv = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
                "docs"]
    errors = []
    n = 0
    for md in iter_md_files(argv):
        n += 1
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"linkcheck: {n} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
